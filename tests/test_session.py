"""CompilerSession front door: records, shared context, shims, artifacts."""
import json
import os
import re

import pytest

from repro.compiler import (
    SCHEMA_VERSION,
    ArtifactSet,
    BudgetPolicy,
    CompilerSession,
    TuningRecord,
    TuningRecords,
    attention_task,
    gemm_task,
    migrate_json_cache,
    record_key,
    tasks_for_config,
)


def _rec(key="tpu-v5e:gemm[i=64,j=128,k=128]", **kw):
    base = dict(
        key=key, kind="gemm", params={"bm": 64, "bn": 128, "bk": 128},
        speedup=3.0, samples=10, method="llm-mcts",
    )
    base.update(kw)
    return TuningRecord(**base)


# ---------------------------------------------------------------------------
# record store
# ---------------------------------------------------------------------------


def test_records_roundtrip_and_dedup(tmp_path):
    path = os.path.join(tmp_path, "db.jsonl")
    db = TuningRecords(path)
    db.add(_rec(speedup=2.0, created_at=1.0))
    db.add(_rec(key="tpu-v5e:gemm[i=8,j=8,k=8]",
                params={"bm": 8, "bn": 8, "bk": 8}))
    db.add(_rec(speedup=5.0, created_at=2.0))  # same key: newest wins
    fresh = TuningRecords(path)
    assert len(fresh) == 2
    assert fresh.get("tpu-v5e:gemm[i=64,j=128,k=128]").speedup == 5.0
    # provenance is stamped on every record
    for rec in fresh.all():
        assert rec.schema == SCHEMA_VERSION
        assert rec.provenance.get("cost_model")
    assert [r.kind for r in fresh.query(kind="gemm")] == ["gemm", "gemm"]


def test_records_cross_process_merge(tmp_path):
    """Two sessions appending to the same db path must merge, not clobber."""
    path = os.path.join(tmp_path, "db.jsonl")
    a = TuningRecords(path)
    b = TuningRecords(path)  # opened before a writes anything
    a.add(_rec(key="p:w1[i=1]", kind="gemm",
               params={"bm": 8, "bn": 8, "bk": 8}))
    b.add(_rec(key="p:w2[i=2]", kind="gemm",
               params={"bm": 16, "bn": 16, "bk": 16}))
    # each sees its own write plus the other's after reload
    a.reload()
    b.reload()
    assert a.keys() == b.keys() == ["p:w1[i=1]", "p:w2[i=2]"]
    # and a fresh load of the file sees both appended lines
    assert len(TuningRecords(path)) == 2


def test_records_corrupt_lines_quarantined(tmp_path):
    path = os.path.join(tmp_path, "db.jsonl")
    db = TuningRecords(path)
    db.add(_rec())
    with open(path, "a") as f:
        f.write("{truncated-mid-wri\n")
        f.write("[1, 2, 3]\n")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        fresh = TuningRecords(path)
    assert len(fresh) == 1  # the good record survives
    assert fresh.quarantined == 2
    assert os.path.exists(path + ".quarantined")
    # the store was compacted: corrupt lines quarantine exactly once, the
    # next load is clean and does not re-warn
    import warnings as _warnings

    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        again = TuningRecords(path)
    assert len(again) == 1 and again.quarantined == 0
    assert len(open(path + ".quarantined").read().splitlines()) == 2


def test_corrupt_legacy_cache_quarantined_not_crash(tmp_path):
    """Regression: a corrupt/truncated legacy JSON tuning cache used to
    crash with json.JSONDecodeError at construction; the record store
    must warn-and-quarantine instead, and a session over it proceeds."""
    cache = os.path.join(tmp_path, "cache.json")
    with open(cache, "w") as f:
        f.write('{"tpu-v5e:gemm[i=64,j=128,k=128]": {"bm": 64, "bn"')
    with pytest.warns(RuntimeWarning, match="quarantined"):
        records = TuningRecords(os.path.join(tmp_path, "c.jsonl"),
                                legacy_json=cache)
    # the corrupt file was moved aside and tuning proceeds
    assert os.path.exists(cache + ".quarantined")
    s = CompilerSession(target="tpu-v5e", method="mcts", budget_policy=6,
                        records=records, shared_context=False)
    (art,) = s.compile([gemm_task(64, 128, 128)])
    b = art.blocks
    assert 64 % b.bm == 0 and 128 % b.bn == 0 and 128 % b.bk == 0


def test_migrate_cache_roundtrip(tmp_path):
    legacy = {
        "tpu-v5e:gemm[i=64,j=128,k=128]": {
            "bm": 64, "bn": 128, "bk": 128, "speedup": 3.21,
            "samples": 12, "method": "llm-mcts",
        },
        "tpu-v5e:attn.kv2[h=8,i=256,j=256,k=64]": {
            "block_q": 64, "block_k": 128, "speedup": 7.5, "samples": 20,
            "method": "llm-mcts", "measured_latency_s": 1e-4,
            "provenance": {"oracle": "measured", "interpret": True},
        },
    }
    src = os.path.join(tmp_path, "tuning_cache.json")
    with open(src, "w") as f:
        json.dump(legacy, f)
    db = TuningRecords(os.path.join(tmp_path, "records.jsonl"))
    assert migrate_json_cache(src, db) == 2
    attn = db.get("tpu-v5e:attn.kv2[h=8,i=256,j=256,k=64]")
    assert attn.kind == "attention" and attn.measured
    assert attn.dims == {"h": 8, "i": 256, "j": 256, "k": 64}
    assert attn.provenance["migrated_from"] == "v0-json"
    # round trip: exporting the legacy view reproduces the v0 entries
    out = os.path.join(tmp_path, "export.json")
    db.export_json(out)
    exported = json.load(open(out))
    for key, entry in legacy.items():
        for field, val in entry.items():
            if field == "provenance":
                continue  # enriched with migration provenance
            assert exported[key][field] == val
    # re-migrating is a no-op (existing records are not older)
    assert migrate_json_cache(src, db) == 0


def test_migrate_persists_even_when_store_prefolded_legacy(tmp_path):
    """Regression: a store constructed with legacy_json= already holds the
    v0 records in memory; migration must still WRITE them to the JSONL
    file (the default --migrate-cache path), not silently no-op."""
    src = os.path.join(tmp_path, "tuning_cache.json")
    with open(src, "w") as f:
        json.dump({"tpu-v5e:gemm[i=8,j=8,k=8]":
                   {"bm": 8, "bn": 8, "bk": 8, "speedup": 2.0,
                    "samples": 4, "method": "mcts"}}, f)
    jsonl = os.path.join(tmp_path, "records.jsonl")
    db = TuningRecords(jsonl, legacy_json=src)  # fold happens at load
    assert len(db) == 1 and not os.path.exists(jsonl)
    assert migrate_json_cache(src, db) == 1
    assert len(TuningRecords(jsonl)) == 1       # actually on disk now
    assert migrate_json_cache(src, db) == 0     # and re-running is a no-op


def test_migrate_cache_cli(tmp_path, capsys):
    from repro.launch import tune

    src = os.path.join(tmp_path, "cache.json")
    with open(src, "w") as f:
        json.dump({"tpu-v5e:gemm[i=8,j=8,k=8]":
                   {"bm": 8, "bn": 8, "bk": 8, "speedup": 1.5,
                    "samples": 4, "method": "mcts"}}, f)
    dst = os.path.join(tmp_path, "records.jsonl")
    assert tune.main(["--migrate-cache", src, "--records", dst]) == 0
    assert "migrated 1 record(s)" in capsys.readouterr().out
    assert len(TuningRecords(dst)) == 1


# ---------------------------------------------------------------------------
# session compile
# ---------------------------------------------------------------------------


def test_session_compile_persists_records(tmp_path):
    path = os.path.join(tmp_path, "records.jsonl")
    s = CompilerSession(target="core-i9", method="mcts", budget_policy=8,
                        records=path)
    tasks = [gemm_task(64, 128, 128), gemm_task(32, 128, 128)]
    arts = s.compile(tasks)
    assert len(TuningRecords(path)) == 2
    for art, task in zip(arts, tasks):
        assert art.task is task
        assert art.record.key == record_key("core-i9", task.workload)
        assert art.record.samples >= 1
        assert art.record.provenance["oracle"] == "analytical"
    # a second session over the same db resolves both as cache hits
    s2 = CompilerSession(target="core-i9", method="mcts", budget_policy=8,
                         records=path)
    arts2 = s2.compile(tasks)
    assert all(a.cache_hit for a in arts2)
    assert s2.samples_spent == 0
    assert [a.record.params for a in arts2] == \
        [a.record.params for a in arts]


def test_session_budget_reallocation():
    """Converged tasks donate unspent budget to stragglers."""
    policy = BudgetPolicy(total=40, patience=4, early_stop=True,
                          reallocate=True)
    s = CompilerSession(target="core-i9", method="mcts",
                        budget_policy=policy, shared_context=False)
    tasks = [gemm_task(64, 128, 128, priority=10),
             gemm_task(128, 256, 256)]
    arts = s.compile(tasks)
    used0 = arts[0].record.samples
    granted1 = arts[1].record.provenance["budget_granted"]
    # the first task's unspent budget flowed into the second's grant
    assert granted1 == 40 - used0
    assert s.samples_spent <= 40


def test_budget_total_is_a_hard_ceiling():
    """Regression: the min_samples floor let compile() overrun an explicit
    total; with a measured oracle every extra sample is hardware time."""
    s = CompilerSession(
        target="core-i9", method="mcts",
        budget_policy=BudgetPolicy(total=8, early_stop=False),
    )
    arts = s.compile([gemm_task(64, 128, 128), gemm_task(32, 64, 64),
                      gemm_task(128, 128, 128), gemm_task(16, 64, 64)])
    assert s.samples_spent <= 8
    # pool-starved tasks still produce a (0-sample, unoptimized) record
    starved = [a for a in arts
               if a.record.provenance["budget_granted"] == 0]
    assert starved and all(a.record.samples == 0 for a in starved)


def test_migrate_never_degrades_searched_records(tmp_path):
    """Regression: migrating the legacy JSON *mirror* (written from the
    rich records, hence newer mtime) must not clobber the winning trace
    and provenance of the searched records it was derived from."""
    path = os.path.join(tmp_path, "records.jsonl")
    s = CompilerSession(target="core-i9", method="mcts", budget_policy=6,
                        records=path)
    (art,) = s.compile([gemm_task(64, 128, 128)])
    assert art.record.history
    mirror = os.path.join(tmp_path, "mirror.json")
    s.records.export_json(mirror)
    db = TuningRecords(path)
    assert migrate_json_cache(mirror, db) == 0  # nothing to migrate
    rich = TuningRecords(path).get(art.record.key)
    assert tuple(rich.history) == tuple(art.record.history)
    assert "migrated_from" not in rich.provenance


def test_no_reallocation_grants_stay_even():
    """reallocate=False must grant every task its even share regardless of
    what earlier tasks spent (regression: the pool was decremented)."""
    s = CompilerSession(
        target="core-i9", method="mcts",
        budget_policy=BudgetPolicy(per_task=10, early_stop=False,
                                   reallocate=False),
        shared_context=False,
    )
    arts = s.compile([gemm_task(64, 128, 128), gemm_task(128, 256, 256),
                      gemm_task(32, 64, 64)])
    assert [a.record.provenance["budget_granted"] for a in arts] \
        == [10, 10, 10]


def test_donor_provenance_only_when_seeding_possible():
    """mcts/evolutionary never consume a donor, so their records must not
    claim seeded_from (regression: corrupted the ablation data)."""
    donor = attention_task(4, 128, 128, 64, priority=10)
    sibling = attention_task(4, 256, 256, 64)
    s = CompilerSession(target="core-i9", method="mcts", budget_policy=6,
                        shared_context=True)
    arts = s.compile([donor, sibling])
    assert "seeded_from" not in arts[1].record.provenance


def test_per_call_llm_mcts_override_uses_session_proposer():
    """A per-call method='llm-mcts' on a non-llm session must build the
    LLM from the session's configured proposer spec, once (regression:
    it silently fell back to a fresh hard-coded gpt-4o-mini)."""
    s = CompilerSession(target="core-i9", method="mcts",
                        proposer="llama3.1-8b")
    r = s.search(gemm_task(64, 128, 128).workload, budget=6, seed=0,
                 method="llm-mcts")
    assert r.llm == "llama3.1-8b" == s.llm_name
    llm = s.llm
    s.search(gemm_task(32, 64, 64).workload, budget=4, seed=0,
             method="llm-mcts")
    assert s.llm is llm  # one LLM per session, not one per call


def test_family_stats_feed_cross_task_hint():
    s = CompilerSession(target="core-i9", budget_policy=12)
    task = gemm_task(64, 128, 128)
    (art,) = s.compile([task])
    assert art.result.family_stats  # tree-edge plateau statistics recorded
    donor = s.context.outcomes[task.family_key]
    assert donor.prefer  # distilled into the prefer/avoid hint
    assert donor.prefer.isdisjoint(donor.avoid)


def test_shared_context_reaches_isolated_best_in_fewer_samples():
    """Acceptance: with shared context, the sibling search reaches the
    isolated search's best speedup in FEWER samples (deterministic
    heuristic LLM, analytical oracle)."""
    donor = attention_task(4, 256, 256, 64, priority=10)
    sibling = attention_task(4, 512, 512, 64)
    budget = 48

    iso = CompilerSession(
        target="tpu-v5e", shared_context=False,
        budget_policy=BudgetPolicy(per_task=budget, early_stop=False),
    )
    (iso_art,) = iso.compile([sibling])
    iso_best = iso_art.record.speedup
    iso_reach = iso_art.result.curve.samples_to_reach(iso_best * 0.999)

    shared = CompilerSession(
        target="tpu-v5e", shared_context=True,
        budget_policy=BudgetPolicy(per_task=budget, early_stop=False),
    )
    arts = shared.compile([donor, sibling])
    sib_art = arts[1]
    assert sib_art.record.provenance.get("seeded_from") \
        == donor.workload.name
    shared_reach = sib_art.result.curve.samples_to_reach(iso_best)
    assert shared_reach is not None, \
        "shared-context search never reached the isolated best"
    assert shared_reach < iso_reach, (shared_reach, iso_reach)
    assert shared.seeds_played >= 1


def test_session_records_winning_trace():
    s = CompilerSession(target="core-i9", budget_policy=10)
    (art,) = s.compile([gemm_task(64, 128, 128)])
    assert art.record.history  # the winning transform trace is persisted
    # the schedule replays from the record's trace
    sched = art.schedule()
    assert sched.history
    from repro.compiler import blocks_from_record

    assert blocks_from_record(art.record).__dict__ == art.blocks.__dict__


# ---------------------------------------------------------------------------
# deprecation aliases (registry binding is the one entry point)
# ---------------------------------------------------------------------------


def test_one_shot_search_matches_session():
    from repro.core.search import _one_shot_search

    w = gemm_task(64, 256, 256).workload
    one = _one_shot_search(w, "core-i9", "llm-mcts", budget=16, seed=3)
    session = CompilerSession(target="core-i9", method="llm-mcts",
                              shared_context=False)
    via = session.search(w, budget=16, seed=3)
    assert one.best_speedup == via.best_speedup
    assert one.samples == via.samples
    assert one.best_schedule.key() == via.best_schedule.key()
    assert one.curve.points == via.curve.points
    assert one.oracle == via.oracle == "analytical"


def test_binding_aliases_warn_and_delegate_to_registry():
    from repro.compiler import (
        ArtifactRegistry,
        artifacts_for_config,
        bind_artifacts,
    )
    from repro.configs import get_config

    cfg = get_config("tinyllama-1.1b")
    with pytest.warns(DeprecationWarning, match="ArtifactRegistry"):
        art = artifacts_for_config(cfg, tp=2, records=TuningRecords(None))
    assert isinstance(art, ArtifactSet) and art.tp == 2
    with pytest.warns(DeprecationWarning, match="ArtifactRegistry"):
        bound, tp = bind_artifacts(cfg, tp=2)
    assert tp == 2 and bound.artifacts is not None
    with pytest.warns(DeprecationWarning, match="ArtifactRegistry"):
        via_cfg = cfg.with_artifacts(art)
    assert via_cfg.artifacts is art
    # the registry entry point itself is warning-free
    import warnings as _warnings

    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        reg = ArtifactRegistry(TuningRecords(None))
        bound2, tp2 = reg.bind(cfg, tp=2)
    assert tp2 == 2 and bound2.artifacts.tp == 2
    assert bound2.artifacts.epoch == reg.epoch


# ---------------------------------------------------------------------------
# deploy-time artifacts
# ---------------------------------------------------------------------------


def test_artifact_set_resolves_session_records(tmp_path):
    from repro.compiler import local_attention_dims
    from repro.configs import get_config

    cfg = get_config("tinyllama-1.1b")
    tp = 4
    hq, hkv = local_attention_dims(cfg, tp)
    path = os.path.join(tmp_path, "records.jsonl")
    s = CompilerSession(target="tpu-v5e", budget_policy=10, records=path)
    (art,) = s.compile([attention_task(hq, 128, 128, cfg.hd,
                                       kv_heads=hkv)])
    artset = ArtifactSet(TuningRecords(path), tp=tp)
    assert artset.attention_blocks(cfg, 128, 128) == \
        (art.blocks.block_q, art.blocks.block_k)
    # a miss returns kernel defaults, never searches
    assert artset.attention_blocks(cfg, 64, 64) == (128, 128)
    assert ArtifactSet(TuningRecords(path), tp=1) \
        .attention_blocks(cfg, 128, 128) == (128, 128)  # other tp: miss


def test_attention_block_uses_cfg_artifacts(tmp_path, monkeypatch):
    """attention_block must resolve blocks from the artifact set bound on
    cfg — no module global involved."""
    import jax
    import jax.numpy as jnp

    from repro.compiler import local_attention_dims
    from repro.configs import get_config
    from repro.kernels import ops
    from repro.models import layers as L

    cfg = get_config("tinyllama-1.1b")
    tp = 4
    hq, hkv = local_attention_dims(cfg, tp)
    path = os.path.join(tmp_path, "records.jsonl")
    s = CompilerSession(target="tpu-v5e", budget_policy=10, records=path)
    (art,) = s.compile([attention_task(hq, 128, 128, cfg.hd,
                                       kv_heads=hkv)])
    import dataclasses

    bound = dataclasses.replace(
        cfg, artifacts=ArtifactSet(TuningRecords(path), tp=tp)
    )
    assert bound.artifacts is not None and cfg.artifacts is None
    assert bound == cfg  # artifacts are excluded from config identity

    seen = {}
    real_attention = ops.attention

    def spy(q, k, v, **kw):
        seen.update(kw)
        return real_attention(q, k, v, **kw)

    monkeypatch.setattr(ops, "attention", spy)
    dims = L.AttnDims(heads=hq, kv_heads=hkv, hd=cfg.hd, d_model=128)
    p = L.init_attention(jax.random.PRNGKey(0), dims, jnp.float32)
    x = jnp.zeros((1, 128, 128), jnp.float32)
    pos = jnp.arange(128)[None]
    # note: NO set_active_tp — the tp degree travels inside cfg.artifacts
    L.attention_block(x, p, dims, pos, cfg=bound, backend="jax")
    assert (seen["block_q"], seen["block_k"]) == \
        (art.blocks.block_q, art.blocks.block_k)


def test_serve_engine_binds_artifact_set():
    import jax

    from repro.configs import get_config
    from repro.models import model as M
    from repro.serve.engine import ServeEngine

    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=32, backend="jax")
    assert isinstance(eng.cfg.artifacts, ArtifactSet)
    assert eng.cfg.artifacts.tp == 1


def test_no_set_active_tp_anywhere_in_src():
    """Acceptance: the set_active_tp module-global shim is GONE — not a
    definition, not a call site, nowhere in src/ (binding travels inside
    cfg.artifacts via ArtifactRegistry.bind)."""
    root = os.path.join(os.path.dirname(__file__), "..", "src")
    offenders = []
    for dirpath, _, files in os.walk(root):
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            for i, line in enumerate(open(path).read().splitlines(), 1):
                if re.search(r"\b(set_active_tp|_ACTIVE_TP)\b", line):
                    offenders.append(f"{path}:{i}: {line.strip()}")
    assert not offenders, "\n".join(offenders)


def test_tasks_for_config_covers_hot_kernels():
    from repro.configs import get_config

    cfg = get_config("tinyllama-1.1b")
    tasks = tasks_for_config(cfg, 256, tp=4)
    kinds = [t.kind for t in tasks]
    assert kinds.count("attention") == 1 and kinds.count("gemm") >= 3
    attn = tasks[0].workload
    assert attn.loop_map["h"].extent == 8  # tp-local query heads
    assert ".kv1" in attn.name             # replicated kv under tp=4
    # MoE arch adds the expert GEMM
    moe = get_config("qwen3-moe-30b-a3b", smoke=True)
    moe_tasks = tasks_for_config(moe, 256)
    assert len([t for t in moe_tasks if "expert" in t.label]) == 1


def test_tune_cli_seq_sweep(tmp_path, capsys):
    from repro.launch import tune

    dst = os.path.join(tmp_path, "records.jsonl")
    assert tune.main([
        "--arch", "tinyllama-1.1b", "--seqs", "64,128", "--tp", "4",
        "--budget", "4", "--method", "mcts", "--no-measure",
        "--records", dst,
    ]) == 0
    db = TuningRecords(dst)
    # one attention + one MLP record per shape in the sweep
    attn = db.query(kind="attention")
    gemm = db.query(kind="gemm")
    assert len(attn) == 2 and len(gemm) == 2
    assert sorted(r.dims["i"] for r in attn) == [64, 128]
    assert sorted(r.dims["i"] for r in gemm) == [64, 128]
