"""Sharding rules (structure-level, 1-device mesh) + roofline HLO parsing."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, input_specs
from repro.dist import sharding as shd
from repro.models import model as M
from repro.roofline.analysis import (
    Roofline,
    CollectiveStats,
    parse_collectives,
    _shape_bytes,
)

MESH = jax.make_mesh((1, 1), ("data", "model"))


def _abstract_params(cfg, tp=1):
    return jax.eval_shape(
        lambda k: M.init_params(cfg, k, tp),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )


def test_param_specs_cover_tree():
    for arch in ("tinyllama-1.1b", "qwen3-moe-30b-a3b", "xlstm-125m",
                 "hymba-1.5b", "hubert-xlarge"):
        cfg = get_config(arch, smoke=True)
        params = _abstract_params(cfg)
        specs = shd.param_specs(cfg, params, MESH)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            assert len(spec) <= len(leaf.shape), (arch, leaf.shape, spec)


def test_tp_rules():
    cfg = get_config("tinyllama-1.1b")
    params = _abstract_params(cfg)
    specs = shd.param_specs(cfg, params, MESH)
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
    # kv heads (4) < tp on a big mesh would replicate; on tp=1 they shard
    assert specs["layers"]["mlp"]["w_gate"] == P(None, None, "model")
    assert specs["layers"]["mlp"]["w_down"] == P(None, "model", None)
    assert specs["embed"] == P("model", None)


def test_moe_expert_parallel_rule():
    cfg = get_config("qwen3-moe-30b-a3b")
    params = _abstract_params(cfg)
    specs = shd.param_specs(cfg, params, MESH)
    assert specs["layers"]["moe"]["w_gate"] == P(None, "model", None, None)
    assert specs["layers"]["moe"]["w_router"] == P(None, None, None)


def test_batch_specs_replicate_non_divisible():
    cfg = get_config("tinyllama-1.1b")
    batch = {"tokens": jax.ShapeDtypeStruct((3, 8), jnp.int32)}
    spec = shd.batch_specs(cfg, batch, MESH)["tokens"]
    # batch 3 divisible by data=1 -> sharded over ("data",)
    assert spec == P(("data",), None)


def test_paged_cache_specs():
    """Page-pool leaves: KV heads over "model", page axis replicated."""
    from repro.serve.kvcache import PagedKVCache

    cfg = get_config("tinyllama-1.1b", smoke=True)
    kv = PagedKVCache(cfg, slots=2, max_len=32, page_size=16)
    specs = shd.paged_cache_specs_tree(cfg, kv.pool, MESH)
    assert specs["k"] == P(None, None, "model", None, None)
    assert specs["v"] == P(None, None, "model", None, None)
    assert specs["kv_pos"] == P(None, None, None)


def test_zero1_opt_sharding():
    cfg = get_config("tinyllama-1.1b")
    params = _abstract_params(cfg)
    pspecs = shd.param_specs(cfg, params, MESH)
    ospecs = shd.opt_state_specs(pspecs, params, MESH)
    # wq [L, D, H*hd]: param (None, None, model) -> opt shards D over data
    assert ospecs["layers"]["attn"]["wq"] == P(None, "data", "model")


def test_shape_bytes_parser():
    assert _shape_bytes("bf16[16,128]") == 16 * 128 * 2
    assert _shape_bytes("f32[8]") == 32
    assert _shape_bytes("(f32[4,4], bf16[2])") == 64 + 4
    assert _shape_bytes("pred[]") == 1


def test_parse_collectives_synthetic_hlo():
    hlo = """
  %ag.1 = bf16[64,128]{1,0} all-gather(bf16[4,128]{1,0} %x), replica_groups={{0,1,2,3}}
  %ar.2 = f32[1024]{0} all-reduce(f32[1024]{0} %y), replica_groups={{0,256}}, to_apply=%add
  %rs = f32[32]{0} reduce-scatter(f32[128]{0} %z), replica_groups={{0,1}}
  %done = bf16[64,128]{1,0} all-gather-done(%ag.1)
  %notacoll = f32[2]{0} add(f32[2]{0} %a, f32[2]{0} %b)
"""
    st = parse_collectives(hlo, chips_per_pod=256)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1}
    # all-reduce group {0,256} crosses pods -> DCN
    assert st.dcn_bytes == 1024 * 4 * 2.0
    assert st.ici_bytes == 64 * 128 * 2 + 32 * 4


def test_roofline_terms():
    st = CollectiveStats({}, {}, ici_bytes=150e9, dcn_bytes=0.0)
    r = Roofline(
        arch="a", shape="s", mesh="16x16", chips=256,
        hlo_flops=197e12 * 256, hlo_bytes=819e9 * 256 * 0.5,
        collective=st, model_flops=197e12 * 256 * 0.5,
    )
    assert np.isclose(r.compute_s, 1.0)
    assert np.isclose(r.memory_s, 0.5)
    assert np.isclose(r.collective_s, 1.0)
    assert r.dominant in ("compute", "collective")
    assert np.isclose(r.useful_flops_ratio, 0.5)
    assert 0 < r.mfu <= 1


def test_input_specs_shapes():
    cfg = get_config("tinyllama-1.1b")
    sp = input_specs(cfg, "train_4k")
    assert sp["tokens"].shape == (256, 4096)
    sp = input_specs(cfg, "decode_32k")
    assert sp["tokens"].shape == (128, 1)
    enc = get_config("hubert-xlarge")
    sp = input_specs(enc, "prefill_32k")
    assert sp["frames"].shape == (32, 32768, 512)
