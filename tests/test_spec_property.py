"""Property test for the speculative fork/verify/merge page invariants.

Hypothesis drives ``run_spec_ops`` (tests/test_speculative.py) — an
interpreter over random admit / draft-write / accept / reject / fork /
rollback / release interleavings that checks pool conservation
(free + live == capacity, refcounts == holders, no double-free) and
rejected-draft invisibility after every op.  The seeded variant in
test_speculative.py keeps baseline coverage when the dev deps are
absent; this file widens the search space.
"""
import pytest

pytest.importorskip("hypothesis", reason="dev dependency (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_speculative import run_spec_ops  # noqa: E402

_OPS = st.lists(
    st.tuples(st.integers(0, 4), st.integers(0, 999)),
    min_size=1, max_size=40,
)


@settings(max_examples=20, deadline=None, derandomize=True)
@given(ops=_OPS)
def test_property_spec_interleavings_conserve_pool(ops):
    run_spec_ops(ops)
