"""Speculative decoding substrate: the multi-token verify step, the
fork/verify/merge page primitives, and a property harness asserting the
PR-5 pool invariants (free + live == capacity, refcounts == holders, no
double-free) hold under random interleavings of fork / draft-write /
accept / reject / rollback / release — and that no rejected-draft token
is ever visible through a surviving slot's gather view."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve.kvcache import (
    NULL_PAGE,
    TRASH_PAGE,
    PagedKVCache,
    scatter_tokens,
)

from test_kvcache import _check_invariants

CFG = get_config("tinyllama-1.1b", smoke=True)


# ---------------------------------------------------------------------------
# verify_step
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_verify_step_matches_full_forward(params):
    """Chunk-verify logits at every position equal the full forward's
    logits at those positions (the target model scoring k drafts in one
    call computes exactly what k sequential steps would have)."""
    rng = np.random.RandomState(0)
    toks = rng.randint(0, CFG.vocab, size=12).astype(np.int32)
    s0 = 7
    _, cache = M.prefill(
        CFG, params, {"tokens": jnp.asarray(toks[:s0])[None]}, 32
    )
    vlg, cache2 = M.verify_step(
        CFG, params, jnp.asarray(toks[s0:])[None], cache, jnp.int32(s0)
    )
    full, _ = M.forward(CFG, params, {"tokens": jnp.asarray(toks)[None]})
    ref = np.asarray(full)[0, s0:]                  # positions s0..11
    got = np.asarray(vlg)[0]
    assert got.shape == ref.shape
    np.testing.assert_allclose(got, ref, atol=2e-3)
    assert (got.argmax(-1) == ref.argmax(-1)).all()
    # the chunk K/V landed at its absolute positions
    kvp = np.asarray(cache2["kv_pos"])[0, 0]
    assert (kvp[:12] == np.arange(12)).all() and (kvp[12:] == -1).all()


def test_verify_step_per_row_length_masking(params):
    """``lengths`` rejects a per-row suffix in place: row b keeps only
    its first lengths[b] chunk tokens in the returned cache."""
    rng = np.random.RandomState(1)
    toks = rng.randint(0, CFG.vocab, size=(2, 6)).astype(np.int32)
    chunk = rng.randint(0, CFG.vocab, size=(2, 4)).astype(np.int32)
    _, cache = M.prefill(CFG, params, {"tokens": jnp.asarray(toks)}, 32)
    lg_ref, _ = M.verify_step(
        CFG, params, jnp.asarray(chunk), cache, jnp.int32(6)
    )
    _, cache2 = M.verify_step(
        CFG, params, jnp.asarray(chunk), cache, jnp.int32(6),
        lengths=jnp.asarray([1, 3], jnp.int32),
    )
    kvp = np.asarray(cache2["kv_pos"])[0]           # [B, r]
    assert (kvp[0, :7] == np.arange(7)).all() and (kvp[0, 7:] == -1).all()
    assert (kvp[1, :9] == np.arange(9)).all() and (kvp[1, 9:] == -1).all()
    # masking only touches kv_pos validity, never the logits
    lg_masked, _ = M.verify_step(
        CFG, params, jnp.asarray(chunk), cache, jnp.int32(6),
        lengths=jnp.asarray([1, 3], jnp.int32),
    )
    np.testing.assert_array_equal(np.asarray(lg_ref),
                                  np.asarray(lg_masked))


# ---------------------------------------------------------------------------
# page primitives: scatter_tokens / fork_slot / rollback
# ---------------------------------------------------------------------------


def _token_rows(n, c, tags):
    """k/v rows [L, n, Hkv, c, hd] where token j of row i is the constant
    ``tags[i][j]`` — recognizable through any gather."""
    spec = M.cache_spec(CFG, n, c)
    L, _, hkv, _, hd = spec["k"].shape
    k = np.zeros((L, n, hkv, c, hd), np.float32)
    for i in range(n):
        for j in range(c):
            k[:, i, :, j, :] = tags[i][j]
    return {"k": jnp.asarray(k), "v": jnp.asarray(k)}


def _commit(kv, slot, start, accepts, tags):
    """Speculative commit helper: write tokens at start+j for each j,
    routing rejected entries (accepts[j] False) to TRASH."""
    c = len(accepts)
    pages = np.full((1, c), TRASH_PAGE, np.int32)
    offs = np.zeros((1, c), np.int32)
    posv = np.full((1, c), -1, np.int32)
    for j, ok in enumerate(accepts):
        if ok:
            p = start + j
            pages[0, j] = kv.table[slot, p // kv.page_size]
            offs[0, j] = p % kv.page_size
            posv[0, j] = p
    kv.pool = scatter_tokens(
        kv.pool, _token_rows(1, c, [tags]), jnp.asarray(pages),
        jnp.asarray(offs), jnp.asarray(posv),
    )


def _visible(kv):
    """{slot: {pos: tag}} as the model would see it through gather_view."""
    view = kv.dense_view()
    kvp = np.asarray(view["kv_pos"])[0]             # [slots, view_len]
    kval = np.asarray(view["k"])[0]                 # [slots, Hkv, vl, hd]
    out = {}
    for s in range(kv.slots):
        out[s] = {
            int(p): float(kval[s, 0, p, 0])
            for p in np.nonzero(kvp[s] >= 0)[0]
        }
        for p, v in out[s].items():
            assert kvp[s, p] == p, "view index != absolute position"
    return out


def test_scatter_tokens_trash_routing():
    kv = PagedKVCache(CFG, slots=2, max_len=32, page_size=4)
    assert kv.reserve(0, 3)
    kv.alloc_upto(0, 9)                              # 3 pages
    _commit(kv, 0, 0, [True] * 4, [10, 11, 12, 13])
    _commit(kv, 0, 4, [True, True, False, False], [14, 15, 666, 667])
    vis = _visible(kv)
    assert vis[0] == {0: 10, 1: 11, 2: 12, 3: 13, 4: 14, 5: 15}
    assert vis[1] == {}                              # untouched slot
    # the null page stayed pristine and rejected tags are nowhere
    assert (np.asarray(kv.pool["kv_pos"])[:, NULL_PAGE] == -1).all()
    assert 666 not in vis[0].values() and 667 not in vis[0].values()
    _check_invariants(kv)


def test_fork_cow_and_rollback():
    """fork shares pages by refcount; a branch write COWs; rollback
    truncates the branch without perturbing the donor."""
    kv = PagedKVCache(CFG, slots=3, max_len=32, page_size=4)
    assert kv.reserve(0, 4)
    kv.alloc_upto(0, 6)                              # pages 0..1 (6 tokens)
    _commit(kv, 0, 0, [True] * 6, list(range(10, 16)))
    kv.fork_slot(0, 1)
    _check_invariants(kv)
    assert kv.page_ids(1) == kv.page_ids(0)
    assert all(kv.refcount(p) == 2 for p in kv.page_ids(0))

    # branch grows: page idx 1 must go private before the write at pos 6
    assert kv.reserve(1, 4)
    copied = kv.ensure_writable(1, 1, 6)
    assert copied and kv.page_ids(1)[1] != kv.page_ids(0)[1]
    _commit(kv, 1, 6, [True], [26])
    vis = _visible(kv)
    assert vis[0] == {i: 10 + i for i in range(6)}   # donor unperturbed
    assert vis[1] == {**{i: 10 + i for i in range(6)}, 6: 26}
    _check_invariants(kv)

    # rollback the branch inside its private page: in-page tail masked
    kv.rollback(1, 5)
    vis = _visible(kv)
    assert vis[1] == {i: 10 + i for i in range(5)}
    assert vis[0] == {i: 10 + i for i in range(6)}
    _check_invariants(kv)

    # rollback into the SHARED page: the private page frees, the shared
    # boundary page COWs so the donor keeps its tail
    freed = kv.rollback(1, 3)
    assert len(freed) == 1
    vis = _visible(kv)
    assert vis[1] == {0: 10, 1: 11, 2: 12}
    assert vis[0] == {i: 10 + i for i in range(6)}
    _check_invariants(kv)

    kv.release(1)
    kv.release(0)
    _check_invariants(kv)
    assert kv.used_pages == 0


def test_rollback_to_zero_frees_everything():
    kv = PagedKVCache(CFG, slots=2, max_len=32, page_size=4)
    assert kv.reserve(0, 3)
    kv.alloc_upto(0, 9)
    _commit(kv, 0, 0, [True] * 9, list(range(30, 39)))
    freed = kv.rollback(0, 0)
    assert len(freed) == 3 and kv.used_pages == 0
    assert _visible(kv)[0] == {}
    assert (kv.table[0] == NULL_PAGE).all()
    _check_invariants(kv)


# ---------------------------------------------------------------------------
# interleaving harness: pool invariants under speculative op sequences
#
# ``run_spec_ops`` interprets a list of (op, arg) pairs as admit /
# speculative-commit (accept + reject) / fork / rollback / release ops
# and checks, after EVERY op, that the pool is conserved
# (free + live == capacity, refcounts == holders — test_kvcache's
# ``_check_invariants``) and that no rejected draft's tag is visible
# through any surviving slot's gather view.  Driven here from seeded
# deterministic sequences; tests/test_spec_property.py feeds it from
# hypothesis when the dev deps are installed.
# ---------------------------------------------------------------------------


def run_spec_ops(ops):
    SLOTS, PG, MAX_LEN, GROW = 3, 4, 32, 8
    kv = PagedKVCache(CFG, slots=SLOTS, max_len=MAX_LEN, page_size=PG,
                      capacity=16)
    model: dict[int, dict[int, float]] = {}      # slot -> pos -> tag
    budget: dict[int, int] = {}
    rejected: set[float] = set()
    tag = [100.0]

    def next_tags(n):
        out = [tag[0] + i for i in range(n)]
        tag[0] += n
        return out

    def check():
        _check_invariants(kv)
        vis = _visible(kv)
        for s, want in model.items():
            assert vis[s] == want, (s, vis[s], want)
        seen = {v for s in vis for v in vis[s].values()}
        assert not (seen & rejected), "rejected draft visible in a view"

    for op, arg in ops:
        slot = arg % SLOTS
        if op == 0 and slot not in model:                     # admit
            plen = 3 + arg % 9
            if not kv.reserve(slot, kv.pages_needed(
                    min(plen + GROW, MAX_LEN))):
                continue
            kv.alloc_upto(slot, plen)
            tags = next_tags(plen)
            _commit(kv, slot, 0, [True] * plen, tags)
            model[slot] = dict(enumerate(tags))
            budget[slot] = min(plen + GROW, MAX_LEN)
        elif op == 1 and slot in model:                       # spec round
            pos0 = len(model[slot])
            k_eff = min(3, budget[slot] - pos0)
            if k_eff <= 0:
                continue
            need = kv.pages_needed(pos0 + k_eff) \
                - len(kv.page_ids(slot))
            cows = sum(
                kv.refcount(p) > 1
                for p in kv.page_ids(slot)[pos0 // PG:]
            )
            if len(kv._free) < need + cows:
                continue       # a real engine reserves for this up front
            kv.alloc_upto(slot, pos0 + k_eff)
            for idx in range(pos0 // PG, (pos0 + k_eff - 1) // PG + 1):
                kv.ensure_writable(slot, idx, pos0)
            m = (arg // 7) % (k_eff + 1)                      # accepted
            tags = next_tags(k_eff)
            _commit(kv, slot, pos0,
                    [j < m for j in range(k_eff)], tags)
            model[slot].update(
                (pos0 + j, tags[j]) for j in range(m)
            )
            rejected.update(tags[m:])
        elif op == 2:                                         # fork
            dst = (arg // 7) % SLOTS
            if slot not in model or dst in model or dst == slot:
                continue
            kv.fork_slot(slot, dst)
            model[dst] = dict(model[slot])
            budget[dst] = budget[slot]
        elif op == 3 and slot in model:                       # rollback
            n = (arg // 7) % (len(model[slot]) + 1)
            own = kv.page_ids(slot)
            keep = -(-n // PG) if n else 0
            straddles = keep and n < keep * PG \
                and kv.refcount(own[keep - 1]) > 1
            if straddles and not kv._free:
                continue
            kv.rollback(slot, n)
            model[slot] = {p: t for p, t in model[slot].items()
                           if p < n}
        elif op == 4 and slot in model:                       # release
            kv.release(slot)
            del model[slot]
            del budget[slot]
        else:
            continue
        check()

    for slot in list(model):
        kv.release(slot)
    _check_invariants(kv)
    assert kv.used_pages == 0, "page leak after draining all slots"


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_spec_interleavings_conserve_pool(seed):
    """Seeded random op sequences through the interleaving harness —
    always-on coverage of the same invariants the hypothesis property
    test (tests/test_spec_property.py) explores more widely."""
    rng = np.random.RandomState(seed)
    ops = [(int(rng.randint(0, 5)), int(rng.randint(0, 1000)))
           for _ in range(60)]
    run_spec_ops(ops)
