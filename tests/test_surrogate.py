"""Surrogate oracle tier: featurization, record replay, screening, shims.

Covers the acceptance surface of the record-trained surrogate
(``core/surrogate.py``): fixed-length featurization across workloads,
deterministic replay of persisted transform traces, training-set hygiene
over corrupt/legacy/concurrent record stores, the ``screen``/escalate
dispatcher split through MCTS and evolutionary search, session
train-on-open + provenance stamping, and the legacy-entry-point
deprecation shims.
"""
from __future__ import annotations

import json
import math
import os
import random
import re
import warnings

import numpy as np
import pytest

from repro.compiler import BudgetPolicy, CompilerSession, attention_task, gemm_task
from repro.compiler.records import SCHEMA_VERSION, TuningRecord, TuningRecords
from repro.core.cost_model import HardwareOracle, get_platform
from repro.core.oracle import ORACLES, MeasuredOracle, make_oracle
from repro.core.schedule import initial_schedule, random_schedule
from repro.core.surrogate import (
    N_FEATURES,
    RecordSurrogate,
    SurrogateOracle,
    crossval_rank_predictions,
    featurize_schedule,
    parse_transform_desc,
    replay_record,
    workload_family,
)
from repro.core.workloads import attention_workload, matmul_workload

PLATFORM = get_platform("tpu-v5e")


def _pool(w, n, seed=0):
    rng = random.Random(seed)
    s0 = initial_schedule(w)
    pool = {s0.key(): s0}
    guard = 0
    while len(pool) < n and guard < n * 60:
        guard += 1
        try:
            s = random_schedule(rng, s0, rng.randint(1, 6))
        except Exception:
            continue
        pool.setdefault(s.key(), s)
    return list(pool.values())


def _record_for(s, platform="tpu-v5e", speedup=2.0, **over):
    w = s.workload
    d = dict(
        key=f"{platform}:{w.name}[test]",
        kind="attention" if w.epilogue_kind == "softmax" else "gemm",
        params={"bm": 8, "bn": 8, "bk": 8},
        speedup=speedup,
        samples=4,
        method="mcts",
        platform=platform,
        workload=w.name,
        dims={l.name: l.extent for l in w.loops},
        history=tuple(s.history),
        provenance={"dtype_bytes": w.output.dtype_bytes,
                    "epilogue": w.epilogue_kind or "none"},
    )
    d.update(over)
    return TuningRecord(**d)


def _spearman(xs, ys):
    rx = np.argsort(np.argsort(xs)).astype(float)
    ry = np.argsort(np.argsort(ys)).astype(float)
    if rx.std() == 0 or ry.std() == 0:
        return 0.0
    return float(np.corrcoef(rx, ry)[0, 1])


# ---------------------------------------------------------------------------
# featurization
# ---------------------------------------------------------------------------

def test_featurize_fixed_length_across_workloads():
    """One feature space for every workload kind: rows pool into one model."""
    ws = [
        matmul_workload("g", 64, 128, 128, dtype_bytes=4, epilogue="swiglu"),
        matmul_workload("g2", 32, 64, 64),
        attention_workload("a", heads=2, seq_q=128, seq_kv=128, head_dim=64),
    ]
    for w in ws:
        for s in _pool(w, 4):
            x = featurize_schedule(s, PLATFORM)
            assert x.shape == (N_FEATURES,)
            assert np.all(np.isfinite(x))


def test_featurize_distinguishes_schedules():
    w = matmul_workload("g", 64, 128, 128)
    pool = _pool(w, 8, seed=3)
    keys = {tuple(featurize_schedule(s, PLATFORM)) for s in pool}
    assert len(keys) > 1, "featurization collapsed distinct schedules"


# ---------------------------------------------------------------------------
# record replay (describe() inverse)
# ---------------------------------------------------------------------------

def test_parse_transform_desc_round_trip():
    w = attention_workload("a", heads=2, seq_q=64, seq_kv=64, head_dim=64)
    for s in _pool(w, 12, seed=1):
        for desc in s.history:
            parsed = parse_transform_desc(desc)
            assert parsed is not None, desc
            assert parsed.describe() == desc
    for junk in ("", "garbage", "TileSize(i)", "Frobnicate(x=1)"):
        assert parse_transform_desc(junk) is None


@pytest.mark.parametrize("w", [
    matmul_workload("gemm_t", 64, 128, 128, dtype_bytes=2, epilogue="swiglu"),
    attention_workload("attn_t", heads=2, seq_q=64, seq_kv=64, head_dim=64,
                       dtype_bytes=2),
])
def test_replay_record_reproduces_winning_schedule(w):
    """The persisted transform trace replays into the exact Schedule."""
    for s in _pool(w, 6, seed=2):
        rec = _record_for(s)
        replayed = replay_record(rec)
        assert replayed is not None
        assert replayed.key() == s.key()


def test_replay_record_rejects_unreplayable():
    w = matmul_workload("g", 64, 128, 128)
    s = _pool(w, 2, seed=4)[-1]
    assert replay_record(_record_for(s, history=("Frobnicate(x=1)",))) is None
    assert replay_record(_record_for(s, kind="unknown", dims={})) is None


# ---------------------------------------------------------------------------
# training-set hygiene over the records store
# ---------------------------------------------------------------------------

def test_featurization_deterministic_for_fixed_records_file(tmp_path):
    """Same JSONL file -> bit-identical training matrix and predictions."""
    path = str(tmp_path / "records.jsonl")
    store = TuningRecords(path)
    w = matmul_workload("g", 64, 128, 128, dtype_bytes=2)
    pool = _pool(w, 10, seed=5)
    for i, s in enumerate(pool):
        store.add(_record_for(s, speedup=1.0 + 0.2 * i,
                              key=f"tpu-v5e:g[{i}]"))

    models = []
    for _ in range(2):
        m = RecordSurrogate(min_rows=4)
        added = m.train_from_records(TuningRecords(path), PLATFORM)
        assert added == len(pool)
        assert m.skipped_rows == 0
        m.fit()
        models.append(m)
    assert np.array_equal(np.stack(models[0]._xs), np.stack(models[1]._xs))
    probe = pool[3]
    p0 = models[0].predict_rel(probe, PLATFORM)
    p1 = models[1].predict_rel(probe, PLATFORM)
    assert p0 is not None and p0 == p1


def test_train_from_records_skips_stale_and_unreplayable():
    w = matmul_workload("g", 64, 128, 128, dtype_bytes=2)
    good, other = _pool(w, 2, seed=6)
    records = TuningRecords(None)
    records.add(_record_for(good, key="k1"))
    records.add(_record_for(other, key="k2", schema=SCHEMA_VERSION + 1))
    records.add(_record_for(other, key="k3", history=("Frobnicate(x=1)",)))
    records.add(_record_for(other, key="k4", speedup=0.0))
    m = RecordSurrogate(min_rows=1)
    assert m.train_from_records(records, PLATFORM) == 1
    assert m.skipped_rows == 3


def test_corrupt_lines_quarantined_without_poisoning_training(tmp_path):
    """Corrupt/legacy JSONL lines are quarantined on load and never reach
    the training set; the good rows still train."""
    path = str(tmp_path / "records.jsonl")
    w = matmul_workload("g", 64, 128, 128, dtype_bytes=2)
    pool = _pool(w, 4, seed=7)
    seed_store = TuningRecords(None)
    lines = []
    for i, s in enumerate(pool):
        lines.append(_record_for(s, key=f"tpu-v5e:g[{i}]").to_json())
    lines.insert(1, "{truncated-append")
    lines.insert(3, json.dumps({"not": "a record"}))
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")

    with pytest.warns(RuntimeWarning, match="corrupt"):
        store = TuningRecords(path)
    assert store.quarantined == 2
    assert len(store) == len(pool)
    assert os.path.exists(path + ".quarantined")

    m = RecordSurrogate(min_rows=2)
    assert m.train_from_records(store, PLATFORM) == len(pool)
    assert m.skipped_rows == 0
    assert m.trained


def test_concurrent_appends_merge_into_training_set(tmp_path):
    """Two handles on one store path append-interleave; reload folds both
    writers' rows into one training set."""
    path = str(tmp_path / "records.jsonl")
    a, b = TuningRecords(path), TuningRecords(path)
    w = matmul_workload("g", 64, 128, 128, dtype_bytes=2)
    s1, s2 = _pool(w, 2, seed=8)
    a.add(_record_for(s1, key="tpu-v5e:g[a]"))
    b.add(_record_for(s2, key="tpu-v5e:g[b]"))
    assert len(a) == 1 and len(b) == 1
    a.reload()
    assert len(a) == 2

    m = RecordSurrogate(min_rows=1)
    assert m.train_from_records(a, PLATFORM) == 2
    # and a third handle opening fresh sees the same two lines
    m2 = RecordSurrogate(min_rows=1)
    assert m2.train_from_records(TuningRecords(path), PLATFORM) == 2


# ---------------------------------------------------------------------------
# the model + LOO rank quality
# ---------------------------------------------------------------------------

def test_surrogate_ranks_analytical_pool():
    """LOO-crossval surrogate scores rank an analytical-labeled pool
    positively (generalization across held-out schedules)."""
    w = matmul_workload("g", 64, 256, 256, dtype_bytes=4, epilogue="swiglu")
    oracle = HardwareOracle(PLATFORM, noise=False)
    pool = _pool(w, 16, seed=9)
    ys = [oracle.measure(s) for s in pool]
    preds = crossval_rank_predictions(pool, ys, PLATFORM)
    assert len(preds) == len(pool)
    assert _spearman(preds, ys) > 0.3


def test_predict_latency_needs_live_anchor():
    w = matmul_workload("g", 64, 128, 128, dtype_bytes=2)
    pool = _pool(w, 10, seed=10)
    m = RecordSurrogate(min_rows=4)
    records = TuningRecords(None)
    for i, s in enumerate(pool):
        records.add(_record_for(s, key=f"k{i}", speedup=1.0 + 0.1 * i))
    m.train_from_records(records, PLATFORM)
    m.fit()
    s = pool[0]
    assert m.predict_rel(s, PLATFORM) is not None
    # record rows only: no measured-scale anchor for this family yet
    assert m.predict_latency(s, PLATFORM) is None
    m.observe(s, PLATFORM, 1e-4)
    m.fit()
    lat = m.predict_latency(s, PLATFORM)
    assert lat is not None and lat > 0


# ---------------------------------------------------------------------------
# the oracle tier
# ---------------------------------------------------------------------------

def test_make_oracle_surrogate_variants():
    assert "surrogate" in ORACLES
    o = make_oracle("surrogate", "tpu-v5e")
    assert isinstance(o, SurrogateOracle)
    assert isinstance(o.escalate, MeasuredOracle)
    o2 = make_oracle("surrogate:analytical", "tpu-v5e")
    assert isinstance(o2, SurrogateOracle)
    assert isinstance(o2.escalate, HardwareOracle)
    assert o2.platform.name == "tpu-v5e"


def test_screen_undertrained_preserves_pool_order():
    """Undertrained model degrades to the caller's own priority order
    (e.g. LLM proposal first), never to noise."""
    o = SurrogateOracle(HardwareOracle(PLATFORM, noise=False), min_rows=10 ** 6)
    w = matmul_workload("g", 64, 128, 128)
    pool = _pool(w, 6, seed=11)
    assert o.screen(pool, k=2) == pool[:2]
    assert o.proposals == len(pool)
    assert o.escalations == 0


def test_screen_trained_prefers_predicted_fast_and_counts():
    o = SurrogateOracle(HardwareOracle(PLATFORM, noise=False),
                        min_rows=6, retrain_every=4)
    w = matmul_workload("g", 64, 256, 256, dtype_bytes=4)
    pool = _pool(w, 14, seed=12)
    for s in pool[:8]:
        o.measure(s)  # escalations double as training rows
    assert o.escalations == 8
    assert o.model.trained
    picked = o.screen(pool[8:], k=2)
    assert len(picked) == 2 and all(p in pool[8:] for p in picked)
    scores = {s.key(): o.model.predict_rel(s, PLATFORM) for s in pool[8:]}
    best_key = min(scores, key=scores.get)
    assert picked[0].key() == best_key
    prov = o.surrogate_provenance()
    assert prov["escalations"] == 8
    assert prov["proposals"] == len(pool) - 8
    assert prov["version"].startswith("ridge-v1/f")
    assert prov["retrains"] == o.model.retrains >= 1


def test_measure_cached_escalates_once():
    o = SurrogateOracle(HardwareOracle(PLATFORM, noise=False), min_rows=4)
    w = matmul_workload("g", 64, 128, 128)
    s = initial_schedule(w)
    t1, t2 = o.measure(s), o.measure(s)
    assert t1 == t2
    assert o.escalations == 1


def test_workload_family_groups_siblings():
    a1 = attention_workload("x", heads=8, seq_q=1024, seq_kv=1024,
                            head_dim=128)
    a2 = attention_workload("y", heads=8, seq_q=256, seq_kv=256,
                            head_dim=128)
    g = matmul_workload("z", 64, 256, 256, epilogue="swiglu")
    assert workload_family(a1, "tpu-v5e") == workload_family(a2, "tpu-v5e")
    assert workload_family(a1, "tpu-v5e") != workload_family(g, "tpu-v5e")


# ---------------------------------------------------------------------------
# search + session integration
# ---------------------------------------------------------------------------

def test_session_mcts_screened_provenance(tmp_path):
    """MCTS with the surrogate tier: fewer escalations than proposals, and
    the persisted record carries surrogate + dtype/epilogue provenance."""
    path = str(tmp_path / "records.jsonl")
    session = CompilerSession(
        target="tpu-v5e", oracle="surrogate:analytical", method="mcts",
        records=path, shared_context=False,
        budget_policy=BudgetPolicy(per_task=10, early_stop=False),
        escalate_topk=1, screen_width=6,
    )
    arts = session.compile([
        gemm_task(32, 64, 64, epilogue="swiglu", label="t"),
    ], force=True)
    rec = arts[0].record
    sp = rec.provenance.get("surrogate")
    assert sp, "surrogate provenance missing from persisted record"
    assert sp["escalations"] <= sp["proposals"]
    assert sp["version"].startswith("ridge-v1/")
    assert rec.provenance["dtype_bytes"] == 2
    assert rec.provenance["epilogue"] == "swiglu"
    assert rec.speedup >= 1.0


def test_session_trains_on_open_from_records(tmp_path):
    path = str(tmp_path / "records.jsonl")
    first = CompilerSession(
        target="tpu-v5e", oracle="surrogate:analytical", method="mcts",
        records=path, shared_context=False,
        budget_policy=BudgetPolicy(per_task=8, early_stop=False),
    )
    first.compile([gemm_task(32, 64, 64, label="t")], force=True)
    assert len(TuningRecords(path)) >= 1

    second = CompilerSession(
        target="tpu-v5e", oracle="surrogate:analytical", method="mcts",
        records=path, shared_context=False,
    )
    assert isinstance(second.oracle, SurrogateOracle)
    assert second.oracle.trained_from_records >= 1


def test_evolutionary_screened_runs(tmp_path):
    session = CompilerSession(
        target="tpu-v5e", oracle="surrogate:analytical",
        method="evolutionary", records=str(tmp_path / "r.jsonl"),
        shared_context=False,
    )
    r = session.search(
        matmul_workload("evo_t", 32, 64, 64), budget=16, seed=0)
    assert r.best_speedup >= 1.0
    assert session.oracle.proposals > session.oracle.escalations > 0


def test_non_surrogate_paths_have_no_screen():
    """The screened expansion is gated on the oracle exposing ``screen``:
    plain backends must not grow one (seeded-identity contract)."""
    for spec in ("analytical", "measured", "hybrid"):
        o = make_oracle(spec, "tpu-v5e")
        assert not hasattr(o, "screen"), spec


# ---------------------------------------------------------------------------
# retired legacy entry points
# ---------------------------------------------------------------------------

def test_run_search_name_is_gone():
    """run_search spent its one deprecation release as a shim and is now
    deleted; the one-shot primitive lives at core.search._one_shot_search."""
    import repro.core.search as search_mod

    assert not hasattr(search_mod, "run_search")
    assert callable(search_mod._one_shot_search)


def test_kernel_tuner_name_is_gone():
    """KernelTuner spent its one deprecation release as a shim and is now
    deleted; core.autotuner keeps only the compat block/workload helpers."""
    import repro.core.autotuner as autotuner_mod

    assert not hasattr(autotuner_mod, "KernelTuner")
    assert callable(autotuner_mod.attention_tuning_workload)


def test_no_deprecated_entry_points_anywhere_in_src():
    """run_search/KernelTuner are gone entirely: no definition, no call
    site, no mention outside prose — anywhere in src/."""
    root = os.path.join(os.path.dirname(__file__), "..", "src")
    offenders = []
    for dirpath, _, files in os.walk(root):
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            for i, line in enumerate(open(path).read().splitlines(), 1):
                stripped = line.split("#")[0]
                if re.search(r"\b(?:run_search|KernelTuner)\b", stripped) \
                        and '"' not in stripped and "'" not in stripped \
                        and "``" not in line:
                    offenders.append(f"{path}:{i}: {line.strip()}")
    assert not offenders, "\n".join(offenders)
