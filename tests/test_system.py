"""End-to-end system behaviour: the paper's qualitative claims as tests."""
import random

import pytest

from repro.core.search import _one_shot_search
from repro.core.workloads import PAPER_WORKLOADS, get_workload


def test_paper_workloads_present():
    assert set(PAPER_WORKLOADS) == {
        "llama3_8b_attention", "deepseek_r1_moe", "flux_attention",
        "flux_conv", "llama4_scout_mlp",
    }
    # Appendix A shapes: A(1,16,7168) @ B(7168,2048)
    w = get_workload("deepseek_r1_moe")
    assert w.loop_map["i"].extent == 16
    assert w.loop_map["j"].extent == 2048
    assert w.loop_map["k"].extent == 7168


def test_search_finds_real_speedups():
    """Every method must find >1x; llm-mcts must be sample-efficient."""
    r = _one_shot_search("llama4_scout_mlp", "core-i9", "llm-mcts", budget=36,
                   seed=0)
    assert r.best_speedup > 10.0
    assert r.samples <= 36
    assert r.best_schedule is not None
    # winning schedule actually differs from p0
    assert r.best_schedule.history


def test_reasoning_compiler_beats_baselines_at_low_budget():
    """The central claim (Fig. 3) on the paper's ablation platform."""
    wins = 0
    for wname in PAPER_WORKLOADS:
        def mean36(method):
            return sum(
                _one_shot_search(wname, "core-i9", method, budget=36,
                           seed=s).curve.at(36)
                for s in range(3)
            ) / 3
        ours = mean36("llm-mcts")
        base = max(mean36("mcts"), mean36("evolutionary"))
        wins += ours >= base * 0.95
    assert wins >= 4, f"llm-mcts won only {wins}/5 kernels at 36 samples"


def test_tuning_transfers_across_platforms():
    """A schedule tuned for one platform is valid (if not optimal) on all."""
    r = _one_shot_search("flux_conv", "graviton2", "llm-mcts", budget=24, seed=0)
    from repro.core.cost_model import HardwareOracle, get_platform

    for plat in ("core-i9", "xeon-e3", "tpu-v5e"):
        o = HardwareOracle(get_platform(plat))
        t = o.measure(r.best_schedule)  # must not raise
        assert t > 0


def test_deterministic_given_seed():
    a = _one_shot_search("deepseek_r1_moe", "core-i9", "llm-mcts", budget=30,
                   seed=5)
    b = _one_shot_search("deepseek_r1_moe", "core-i9", "llm-mcts", budget=30,
                   seed=5)
    assert a.curve.points == b.curve.points
    assert a.best_speedup == b.best_speedup
