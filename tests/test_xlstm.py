"""xLSTM numerics: the chunkwise-parallel mLSTM must equal the per-step
recurrence oracle for any (dims, length, chunk) combination."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dependency (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models import ssm

KEY = jax.random.PRNGKey(0)


@settings(max_examples=6, deadline=None)
@given(
    d=st.sampled_from([16, 32]),
    heads=st.sampled_from([1, 2]),
    s=st.sampled_from([8, 12, 24]),
    chunk=st.sampled_from([4, 8]),
    seed=st.integers(0, 1000),
)
def test_mlstm_chunkwise_equals_naive(d, heads, s, chunk, seed):
    p = ssm.init_mlstm(jax.random.PRNGKey(seed), d, heads, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, s, d)) * 0.5
    y1, st1 = ssm.mlstm_seq(x, p, heads, chunk=chunk)
    y2, st2 = ssm.mlstm_seq_naive(x, p, heads)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(st1["C"], st2["C"], atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(st1["m"], st2["m"], atol=2e-4, rtol=2e-3)


def test_mlstm_state_continuation():
    d, heads = 32, 2
    p = ssm.init_mlstm(KEY, d, heads, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, d)) * 0.5
    y_full, _ = ssm.mlstm_seq(x, p, heads, chunk=8)
    y1, st1 = ssm.mlstm_seq(x[:, :16], p, heads, chunk=8)
    y2, _ = ssm.mlstm_seq(x[:, 16:], p, heads, state=st1, chunk=8)
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], 1), y_full, atol=1e-4, rtol=1e-3
    )


def test_mlstm_decode_step_matches_seq():
    d, heads = 32, 2
    p = ssm.init_mlstm(KEY, d, heads, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 12, d)) * 0.5
    y_seq, _ = ssm.mlstm_seq(x, p, heads, chunk=4)
    state = None
    outs = []
    state = ssm.mlstm_init_state(1, d, heads)
    for t in range(12):
        y, state = ssm.mlstm_step(x[:, t:t + 1], p, heads, state)
        outs.append(y)
    np.testing.assert_allclose(
        jnp.concatenate(outs, 1), y_seq, atol=1e-4, rtol=1e-3
    )


def test_slstm_seq_equals_steps():
    d, heads = 24, 2
    p = ssm.init_slstm(KEY, d, heads, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 10, d)) * 0.5
    y_seq, _ = ssm.slstm_seq(x, p)
    state = ssm.slstm_init_state(2, d)
    outs = []
    for t in range(10):
        y, state = ssm.slstm_step(x[:, t:t + 1], p, state)
        outs.append(y)
    np.testing.assert_allclose(
        jnp.concatenate(outs, 1), y_seq, atol=1e-5, rtol=1e-4
    )


def test_mlstm_long_context_stability():
    """Exponential gating must stay finite over long sequences."""
    d, heads = 16, 2
    p = ssm.init_mlstm(KEY, d, heads, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 512, d)) * 2.0
    y, st = ssm.mlstm_seq(x, p, heads, chunk=64)
    assert bool(jnp.isfinite(y).all())
    assert bool(jnp.isfinite(st["C"]).all())
